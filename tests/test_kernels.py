"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per instructions: sweep shapes/dtypes per kernel and assert exact equality
(all outputs are integers) against ref.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# bitpack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 31, 32, 33, 4095, 4096, 4097, 70000])
@pytest.mark.parametrize("dtype", [np.uint8, np.int32, np.bool_])
def test_bitpack_shapes_dtypes(n, dtype):
    rng = np.random.default_rng(n)
    bits = rng.integers(0, 2, n).astype(dtype)
    got = np.asarray(ops.bitpack(jnp.asarray(bits)))
    want = np.asarray(ref.bitpack_ref(jnp.asarray(bits.astype(np.uint8))))
    assert np.array_equal(got, want)


@given(st.integers(1, 3000), st.integers(0, 2**32 - 1))
@settings(max_examples=8)
def test_bitpack_property(n, seed):
    bits = np.random.default_rng(seed).integers(0, 2, n).astype(np.uint8)
    got = np.asarray(ops.bitpack(jnp.asarray(bits)))
    want = np.asarray(ref.bitpack_ref(jnp.asarray(bits)))
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# rank_build
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 128, 129, 16384, 16385, 131072, 200000])
def test_rank_build_shapes(n):
    rng = np.random.default_rng(n)
    bits = rng.integers(0, 2, n).astype(np.uint8)
    words = ref.bitpack_ref(jnp.asarray(bits))
    sb, blk = ops.rank_build(words, n)
    sb2, blk2 = ref.rank_build_ref(words, n)
    assert sb.dtype == jnp.uint32 and blk.dtype == jnp.uint16
    assert np.array_equal(np.asarray(sb), np.asarray(sb2))
    assert np.array_equal(np.asarray(blk), np.asarray(blk2))


@given(st.integers(1, 100000), st.floats(0.01, 0.99),
       st.integers(0, 2**32 - 1))
@settings(max_examples=8)
def test_rank_build_property(n, density, seed):
    bits = (np.random.default_rng(seed).random(n) < density).astype(np.uint8)
    words = ref.bitpack_ref(jnp.asarray(bits))
    sb, blk = ops.rank_build(words, n)
    sb2, blk2 = ref.rank_build_ref(words, n)
    assert np.array_equal(np.asarray(sb), np.asarray(sb2))
    assert np.array_equal(np.asarray(blk), np.asarray(blk2))


def test_rank_build_kernel_feeds_rank_queries():
    """Kernel outputs drop into a BinaryRank and answer queries correctly."""
    from repro.core import bitops
    from repro.core.rank_select import BinaryRank, rank1
    rng = np.random.default_rng(9)
    n = 50000
    bits = (rng.random(n) < 0.4).astype(np.uint8)
    words = ref.bitpack_ref(jnp.asarray(bits))
    sb, blk = ops.rank_build(words, n)
    rs = BinaryRank(words=words, superblock=sb, block=blk, n=n)
    idx = rng.integers(0, n + 1, 200)
    got = np.asarray(rank1(rs, jnp.asarray(idx)))
    cum = np.concatenate([[0], np.cumsum(bits)])
    assert np.array_equal(got, cum[idx])


# ---------------------------------------------------------------------------
# wm_level_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 1023, 1024, 1025, 8192, 50000])
@pytest.mark.parametrize("shift", [0, 3, 7])
def test_wm_level_shapes(n, shift):
    rng = np.random.default_rng(n + shift)
    sub = rng.integers(0, 256, n).astype(np.uint32)
    d1, b1, t1 = ops.wm_level_step(jnp.asarray(sub), shift, n)
    d2, b2, t2 = ref.wm_level_step_ref(jnp.asarray(sub), shift, n)
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert int(t1) == int(t2)


@given(st.integers(1, 20000), st.integers(0, 7), st.integers(0, 2**32 - 1))
@settings(max_examples=8)
def test_wm_level_property(n, shift, seed):
    sub = np.random.default_rng(seed).integers(0, 256, n).astype(np.uint32)
    d1, b1, t1 = ops.wm_level_step(jnp.asarray(sub), shift, n)
    d2, b2, t2 = ref.wm_level_step_ref(jnp.asarray(sub), shift, n)
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert int(t1) == int(t2)


def test_wm_level_dest_is_stable_partition():
    """Kernel destinations realize the stable 0/1 partition semantics."""
    rng = np.random.default_rng(11)
    n, shift = 5000, 4
    sub = rng.integers(0, 256, n).astype(np.uint32)
    dest, _, tz = ops.wm_level_step(jnp.asarray(sub), shift, n)
    dest = np.asarray(dest)
    bit = (sub >> shift) & 1
    assert sorted(dest.tolist()) == list(range(n))
    out = np.empty(n, np.int64)
    out[dest] = np.arange(n)
    expect = np.concatenate([np.flatnonzero(bit == 0),
                             np.flatnonzero(bit == 1)])
    assert np.array_equal(out, expect)
    assert int(tz) == int((bit == 0).sum())


# ---------------------------------------------------------------------------
# wm_quantile (fused level descent)
# ---------------------------------------------------------------------------

def _quantile_case(n, sigma, q, seed):
    rng = np.random.default_rng(seed)
    from repro.core import build_wavelet_matrix
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    wm = build_wavelet_matrix(jnp.asarray(seq), sigma, sample_rate=128)
    lo = rng.integers(0, n + 1, q).astype(np.int32)
    hi = rng.integers(0, n + 1, q).astype(np.int32)
    lo, hi = np.minimum(lo, hi), np.maximum(lo, hi)
    k = rng.integers(0, n, q).astype(np.int32)
    return seq, wm, lo, hi, k


@pytest.mark.parametrize("n,sigma", [(33, 2), (777, 5), (1000, 37),
                                     (4096, 256), (1500, 1000)])
def test_wm_quantile_kernel_vs_ref_and_oracle(n, sigma):
    seq, wm, lo, hi, k = _quantile_case(n, sigma, 300, n + sigma)
    got = np.asarray(ops.wm_quantile_batch(wm, jnp.asarray(lo),
                                           jnp.asarray(hi), jnp.asarray(k)))
    want_ref = np.asarray(ref.wm_quantile_ref(
        wm.bitvectors.rank.words, wm.zeros, n,
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(k)))
    assert np.array_equal(got, want_ref)
    for i in range(len(lo)):
        sub = np.sort(seq[lo[i]:hi[i]])
        want = sub[min(k[i], len(sub) - 1)] if len(sub) else -1
        assert got[i] == want, (i, lo[i], hi[i], k[i])


def test_wm_quantile_kernel_agrees_with_analytics_op():
    from repro.analytics import range_quantile
    _, wm, lo, hi, k = _quantile_case(2048, 97, 512, 5)
    got = np.asarray(ops.wm_quantile_batch(wm, jnp.asarray(lo),
                                           jnp.asarray(hi), jnp.asarray(k)))
    want = np.asarray(range_quantile(wm, jnp.asarray(lo), jnp.asarray(hi),
                                     jnp.asarray(k)))
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# wm_level_step_fused (single-launch fused level)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 1023, 1024, 1025, 8192])
@pytest.mark.parametrize("shift", [0, 3, 7])
def test_wm_level_fused_shapes(n, shift):
    rng = np.random.default_rng(n + shift)
    sub = rng.integers(0, 256, n).astype(np.uint32)
    d1, b1, t1 = ops.wm_level_step_fused(jnp.asarray(sub), shift, n)
    d2, b2, t2 = ref.wm_level_step_ref(jnp.asarray(sub), shift, n)
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert int(t1) == int(t2)


def test_wm_level_fused_matches_two_launch_form():
    rng = np.random.default_rng(21)
    n, shift = 5000, 5
    sub = jnp.asarray(rng.integers(0, 256, n).astype(np.uint32))
    d1, b1, t1 = ops.wm_level_step_fused(sub, shift, n)
    d2, b2, t2 = ops.wm_level_step(sub, shift, n)
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert int(t1) == int(t2)


# ---------------------------------------------------------------------------
# rank_build_levels (batched directory build)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 129, 16385, 131072])
def test_rank_build_levels_shapes(n):
    rng = np.random.default_rng(n)
    nlev = 5
    words = jnp.stack([
        ref.bitpack_ref(jnp.asarray(rng.integers(0, 2, n).astype(np.uint8)))
        for _ in range(nlev)])
    sb, blk = ops.rank_build_levels(words, n)
    sb2, blk2 = ref.rank_build_levels_ref(words, n)
    assert sb.dtype == jnp.uint32 and blk.dtype == jnp.uint16
    assert np.array_equal(np.asarray(sb), np.asarray(sb2))
    assert np.array_equal(np.asarray(blk), np.asarray(blk2))


def test_rank_build_levels_matches_per_level_kernel():
    """Row l of the batched launch == the single-row kernel on row l
    (the carry reset at each level row really isolates the rows)."""
    rng = np.random.default_rng(4)
    n, nlev = 40000, 4
    words = jnp.stack([
        ref.bitpack_ref(jnp.asarray((rng.random(n) < p).astype(np.uint8)))
        for p in (0.1, 0.9, 0.5, 0.0)])
    sb, blk = ops.rank_build_levels(words, n)
    for l in range(nlev):
        sb1, blk1 = ops.rank_build(words[l], n)
        assert np.array_equal(np.asarray(sb[l]), np.asarray(sb1)), l
        assert np.array_equal(np.asarray(blk[l]), np.asarray(blk1)), l


# ---------------------------------------------------------------------------
# radix_rank (blocked counting rank)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 1000, 1024, 5000])
@pytest.mark.parametrize("nb", [2, 37, 256, 512])
def test_radix_rank_shapes(n, nb):
    rng = np.random.default_rng(n + nb)
    d = rng.integers(0, nb, n).astype(np.int32)
    got = np.asarray(ops.radix_rank(jnp.asarray(d), nb))
    want = np.asarray(ref.radix_rank_ref(jnp.asarray(d), nb))
    assert np.array_equal(got, want)


def test_radix_rank_is_stable_permutation():
    rng = np.random.default_rng(13)
    n, nb = 4097, 256
    d = rng.integers(0, nb, n).astype(np.int32)
    dest = np.asarray(ops.radix_rank(jnp.asarray(d), nb))
    assert sorted(dest.tolist()) == list(range(n))
    inv = np.empty(n, np.int64)
    inv[dest] = np.arange(n)
    assert np.array_equal(inv, np.argsort(d, kind="stable"))


# ---------------------------------------------------------------------------
# wm_quantile_sharded (fused descent over the stacked (S,)-leaf layout)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,sigma,shard_bits", [(3000, 97, 10),
                                                (4096, 256, 11),
                                                (1500, 5, 9)])
def test_wm_quantile_sharded_kernel(n, sigma, shard_bits):
    from repro.analytics import (build_sharded_analytics,
                                 sharded_range_quantile)
    rng = np.random.default_rng(n + sigma)
    toks = rng.integers(0, sigma, n).astype(np.int64)
    eng = build_sharded_analytics(toks, sigma, shard_bits=shard_bits)
    q = 300
    lo = rng.integers(0, n + 1, q).astype(np.int32)
    hi = rng.integers(0, n + 1, q).astype(np.int32)
    lo, hi = np.minimum(lo, hi), np.maximum(lo, hi)
    k = rng.integers(0, n, q).astype(np.int32)
    got = np.asarray(ops.wm_quantile_sharded_batch(
        eng.shards, eng.shard_bits, eng.n,
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(k)))
    want = np.asarray(sharded_range_quantile(
        eng.shards, eng.shard_bits, eng.n,
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(k)))
    assert np.array_equal(got, want)
    want_ref = np.asarray(ref.wm_quantile_sharded_ref(
        eng.shards.bitvectors.rank.words, eng.shards.zeros,
        eng.shard_bits, eng.n,
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(k)))
    assert np.array_equal(got, want_ref)
    for i in range(32):            # numpy oracle spot check
        sl = np.sort(toks[lo[i]:hi[i]])
        w = sl[min(k[i], len(sl) - 1)] if len(sl) else -1
        assert got[i] == w, (i, lo[i], hi[i], k[i])


# ---------------------------------------------------------------------------
# wt_level (fused segmented tree level step)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 33, 1024, 1500, 2049])
@pytest.mark.parametrize("nodes", [1, 4, 64])
def test_wt_level_fused_shapes(n, nodes):
    rng = np.random.default_rng(n + nodes)
    nid = np.sort(rng.integers(0, nodes, n)).astype(np.int32)
    sub = rng.integers(0, 1 << 8, n).astype(np.uint32)
    for shift in (0, 3, 7):
        dest, bm = ops.wt_level_step_fused(jnp.asarray(sub),
                                           jnp.asarray(nid), shift,
                                           2 * nodes, n, interpret=True)
        dref, bref = ref.wt_level_step_ref(jnp.asarray(sub),
                                           jnp.asarray(nid), shift, n)
        assert np.array_equal(np.asarray(dest), np.asarray(dref)), shift
        assert np.array_equal(np.asarray(bm), np.asarray(bref)), shift


def test_wt_level_fused_dest_is_segmented_partition():
    """dest realizes the stable per-node 0/1 partition exactly."""
    rng = np.random.default_rng(0)
    n, nodes = 3000, 16
    nid = np.sort(rng.integers(0, nodes, n)).astype(np.int32)
    sub = rng.integers(0, 256, n).astype(np.uint32)
    shift = 4
    dest, _ = ops.wt_level_step_fused(jnp.asarray(sub), jnp.asarray(nid),
                                      shift, 2 * nodes, n, interpret=True)
    dest = np.asarray(dest)
    assert np.array_equal(np.sort(dest), np.arange(n))      # a permutation
    out_nid = np.empty(n, np.int32)
    out_bit = np.empty(n, np.int32)
    out_src = np.empty(n, np.int64)
    bit = (sub >> shift) & 1
    out_nid[dest], out_bit[dest], out_src[dest] = nid, bit, np.arange(n)
    key = out_nid * 2 + out_bit
    assert np.all(np.diff(key) >= 0)                        # grouped
    same = key[1:] == key[:-1]
    assert np.all(out_src[1:][same] > out_src[:-1][same])   # stable
