"""Full-text index subsystem: suffix array, BWT, FM-index, sharded index.

All oracles are pure numpy (sorted-suffix comparison, sliding-window
substring match) — no hypothesis required.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_corpus
from repro.index import (build_fm_index, build_sharded_index, bwt_decode,
                         bwt_encode, fm_count, fm_locate, suffix_array,
                         suffix_array_naive)


def _naive_count(text: np.ndarray, pat: np.ndarray, plen: int) -> int:
    if plen > len(text) or plen == 0:
        return 0
    win = np.lib.stride_tricks.sliding_window_view(text, plen)
    return int((win == pat[:plen]).all(axis=1).sum())


def _texts(n: int, sigma: int, seed: int = 0):
    """The three acceptance distributions + adversarial extras."""
    rng = np.random.default_rng(seed)
    zipf = rng.zipf(1.3, n) % sigma
    return {
        "uniform": rng.integers(0, sigma, n).astype(np.int64),
        "skewed": zipf.astype(np.int64),
        "periodic": (np.arange(n) % min(sigma, 7)).astype(np.int64),
        "all_equal": np.full(n, sigma - 1, np.int64),
    }


# ---------------------------------------------------------------------------
# suffix array + BWT
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,sigma", [(1, 2), (2, 2), (13, 4), (100, 4),
                                     (257, 256), (120, 1000)])
def test_suffix_array_matches_naive(n, sigma):
    rng = np.random.default_rng(n * 1000 + sigma)
    for name, seq in _texts(n, sigma, seed=n).items():
        got = np.asarray(suffix_array(jnp.asarray(seq, jnp.int32)))
        assert np.array_equal(got, suffix_array_naive(seq)), (name, n, sigma)
    seq = rng.integers(0, sigma, n)
    assert np.array_equal(np.asarray(suffix_array(jnp.asarray(seq))),
                          suffix_array_naive(seq))


def test_suffix_array_backends_agree():
    rng = np.random.default_rng(7)
    seq = jnp.asarray(rng.integers(0, 16, 300), jnp.int32)
    a = np.asarray(suffix_array(seq, backend="counting"))
    b = np.asarray(suffix_array(seq, backend="xla"))
    assert np.array_equal(a, b)


def test_bwt_roundtrip():
    rng = np.random.default_rng(1)
    for n, sigma in [(1, 2), (50, 3), (400, 256)]:
        seq = rng.integers(0, sigma, n).astype(np.int64)
        bwt, sa, C = bwt_encode(jnp.asarray(seq), sigma)
        assert bwt.shape[0] == n + 1
        assert int(C[-1]) == n + 1
        assert np.array_equal(np.asarray(bwt_decode(bwt, C)), seq)


# ---------------------------------------------------------------------------
# FM-index count/locate vs naive numpy — acceptance distributions & sigmas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sigma", [4, 256, 1000])
def test_fm_count_matches_naive(sigma):
    n, L, B = 300, 6, 24
    for t_i, (name, text) in enumerate(_texts(n, sigma, seed=sigma).items()):
        fm = build_fm_index(jnp.asarray(text, jnp.int32), sigma,
                            sample_rate=16)
        rng = np.random.default_rng(sigma * 10 + t_i)
        pats = np.full((B, L), sigma, np.int32)
        lens = rng.integers(1, L + 1, B).astype(np.int32)
        for i in range(B):
            if i % 3 == 0:   # random pattern — usually a miss
                pats[i, :lens[i]] = rng.integers(0, sigma, lens[i])
            else:            # substring — guaranteed hit
                s = int(rng.integers(0, n - lens[i]))
                pats[i, :lens[i]] = text[s:s + lens[i]]
        got = np.asarray(fm_count(fm, jnp.asarray(pats), jnp.asarray(lens)))
        want = np.array([_naive_count(text, p, int(l))
                         for p, l in zip(pats, lens)])
        assert np.array_equal(got, want), (name, sigma)


def test_fm_count_batch64_under_jit_pytree():
    """Acceptance: ≥64-pattern vmapped batch under jax.jit with the index
    crossing the jit boundary as a pytree argument."""
    n, sigma, L, B = 1024, 256, 8, 64
    rng = np.random.default_rng(0)
    text = rng.integers(0, sigma, n).astype(np.int64)
    fm = build_fm_index(jnp.asarray(text, jnp.int32), sigma)
    pats = np.full((B, L), sigma, np.int32)
    lens = rng.integers(1, L + 1, B).astype(np.int32)
    for i in range(B):
        s = int(rng.integers(0, n - lens[i]))
        pats[i, :lens[i]] = text[s:s + lens[i]]
    f = jax.jit(lambda ix, p, l: ix.count(p, l))
    got = np.asarray(f(fm, jnp.asarray(pats), jnp.asarray(lens)))
    want = np.array([_naive_count(text, p, int(l))
                     for p, l in zip(pats, lens)])
    assert np.array_equal(got, want)
    assert (want >= 1).all()          # every pattern was a real substring


def test_fm_locate_exact_and_subset():
    n, sigma = 400, 8
    rng = np.random.default_rng(3)
    text = rng.integers(0, sigma, n).astype(np.int64)
    fm = build_fm_index(jnp.asarray(text, jnp.int32), sigma, sample_rate=8)
    for plen in (1, 2, 4):
        s = int(rng.integers(0, n - plen))
        pat = text[s:s + plen].astype(np.int32)
        ref = [i for i in range(n - plen + 1)
               if np.array_equal(text[i:i + plen], pat)]
        got = np.asarray(fm_locate(fm, jnp.asarray(pat), jnp.int32(plen),
                                   max_hits=64))
        hits = [int(x) for x in got if x >= 0]
        if len(ref) <= 64:
            assert hits == ref, plen          # all matches, text order
        else:
            assert len(hits) == 64 and set(hits) <= set(ref), plen


def test_fm_locate_adversarial_texts():
    sigma = 4
    for name, text in _texts(200, sigma, seed=5).items():
        fm = build_fm_index(jnp.asarray(text, jnp.int32), sigma,
                            sample_rate=16)
        pat = text[:3].astype(np.int32)
        ref = [i for i in range(198) if np.array_equal(text[i:i + 3], pat)]
        got = np.asarray(fm_locate(fm, jnp.asarray(pat), jnp.int32(3),
                                   max_hits=256))
        hits = [int(x) for x in got if x >= 0]
        assert hits == ref, name


# ---------------------------------------------------------------------------
# sharded index
# ---------------------------------------------------------------------------

def test_sharded_count_matches_global_naive():
    """Seam stitching makes ``count`` exact against the *global* sliding
    oracle — matches crossing shard boundaries included."""
    n, sigma, sb = 2500, 64, 9          # 5 shards of 512, last one padded
    toks = np.asarray(make_corpus(n, sigma, seed=2), np.int64)
    idx = build_sharded_index(toks, sigma, shard_bits=sb, sample_rate=16)
    assert idx.num_shards == 5
    rng = np.random.default_rng(4)
    B, L = 16, 5
    pats = np.full((B, L), sigma, np.int32)
    lens = rng.integers(1, L + 1, B).astype(np.int32)
    for i in range(B):
        s = int(rng.integers(0, n - lens[i]))
        pats[i, :lens[i]] = toks[s:s + lens[i]]
    got = np.asarray(idx.count(jnp.asarray(pats), jnp.asarray(lens)))
    want = np.array([_naive_count(toks, p, int(l))
                     for p, l in zip(pats, lens)])
    assert np.array_equal(got, want)
    # per-shard decomposition still reports within-shard matches only
    S = idx.shard_size
    by_shard = np.asarray(idx.count_by_shard(jnp.asarray(pats),
                                             jnp.asarray(lens)))
    assert by_shard.shape == (5, B)
    want_within = np.array([sum(_naive_count(toks[s0:s0 + S], p, int(l))
                                for s0 in range(0, n, S))
                            for p, l in zip(pats, lens)])
    assert np.array_equal(by_shard.sum(axis=0), want_within)


def test_sharded_count_stitches_planted_seam_matches():
    """Patterns planted *across* every shard seam are found by count."""
    n, sigma, sb = 2048, 32, 9
    rng = np.random.default_rng(11)
    toks = rng.integers(0, sigma, n).astype(np.int64)
    S = 1 << sb
    planted = np.array([9, 4, 9, 4, 9, 4], np.int64)
    for p in range(S, n, S):            # straddle every internal boundary
        toks[p - 3:p + 3] = planted
    idx = build_sharded_index(toks, sigma, shard_bits=sb, sample_rate=16)
    pats_np = np.full((2, 6), sigma, np.int64)
    pats_np[0] = planted
    pats_np[1, :4] = planted[:4]
    pats = jnp.asarray(pats_np, jnp.int32)
    lens = jnp.asarray([6, 4], jnp.int32)
    got = np.asarray(idx.count(pats, lens))
    for i, l in enumerate([6, 4]):
        assert got[i] == _naive_count(toks, np.asarray(pats[i]), l), i
    # seam contribution alone equals global minus within-shard
    by_shard = np.asarray(idx.count_by_shard(pats, lens)).sum(axis=0)
    assert (got - by_shard >= idx.num_shards - 1).all()

    # overlap 0 disables stitching → within-shard counts only
    idx0 = build_sharded_index(toks, sigma, shard_bits=sb, sample_rate=16,
                               seam_overlap=0)
    got0 = np.asarray(idx0.count(pats, lens))
    assert np.array_equal(got0, by_shard)


def test_sharded_locate_positions_are_real_matches():
    n, sigma, sb = 1200, 16, 9
    rng = np.random.default_rng(6)
    toks = rng.integers(0, sigma, n).astype(np.int64)
    idx = build_sharded_index(toks, sigma, shard_bits=sb, sample_rate=16)
    pats = np.full((4, 3), sigma, np.int32)
    for i in range(4):
        s = int(rng.integers(0, n - 3))
        pats[i] = toks[s:s + 3]
    lens = np.full(4, 3, np.int32)
    pos = np.asarray(idx.locate(jnp.asarray(pats), jnp.asarray(lens),
                                max_hits_per_shard=8))
    for i in range(4):
        hits = [int(x) for x in pos[i] if x >= 0]
        assert hits, i                        # sampled from corpus → ≥1 hit
        assert hits == sorted(hits)
        for p0 in hits:
            assert np.array_equal(toks[p0:p0 + 3], pats[i]), (i, p0)


def test_sharded_pad_symbol_never_matches_padding():
    """Out-of-vocab query symbols (σ included — the tail-shard pad value)
    must count 0 and locate nothing, not the padding run."""
    sigma = 7
    toks = np.arange(100) % sigma
    idx = build_sharded_index(toks, sigma, shard_bits=6, sample_rate=8)
    pats = jnp.asarray([[sigma, 0], [sigma + 3, 0], [-1, 0]], jnp.int32)
    lens = jnp.asarray([1, 2, 1], jnp.int32)
    assert np.asarray(idx.count(pats, lens)).tolist() == [0, 0, 0]
    pos = np.asarray(idx.locate(pats, lens, max_hits_per_shard=4))
    assert (pos == -1).all()


def test_sharded_tiny_and_padded_shard():
    """Length-1 corpus and a shard that is almost entirely padding."""
    sigma = 8
    idx = build_sharded_index(np.array([3]), sigma, shard_bits=6,
                              sample_rate=4)
    assert idx.num_shards == 1
    got = np.asarray(idx.count(jnp.asarray([[3], [5]], jnp.int32),
                               jnp.asarray([1, 1], jnp.int32)))
    assert got.tolist() == [1, 0]
    pos = np.asarray(idx.locate(jnp.asarray([[3]], jnp.int32),
                                jnp.asarray([1], jnp.int32), 4))
    assert [int(x) for x in pos[0] if x >= 0] == [0]

    # shard boundary: 513 tokens over 512-sized shards → 2nd shard has 1
    toks = np.arange(513) % sigma
    idx2 = build_sharded_index(toks, sigma, shard_bits=9, sample_rate=16)
    assert idx2.num_shards == 2
    got = np.asarray(idx2.count(jnp.asarray([[513 % 8]], jnp.int32),
                                jnp.asarray([1], jnp.int32)))
    want = int((toks == 513 % 8).sum())
    assert int(got[0]) == want
