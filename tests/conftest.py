"""Shared test config. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device (the 512-device override belongs to
the dry-run only).

``hypothesis`` is optional: in minimal environments the property-based
tests auto-skip instead of killing the whole suite at collection. The
shim below installs a stub ``hypothesis`` module whose ``@given`` turns
the test into a zero-argument skipper, so test modules import cleanly.
"""
import sys
import types

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    # jit compilation makes single examples slow; disable deadlines globally.
    settings.register_profile(
        "repro",
        deadline=None,
        max_examples=15,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large],
    )
    settings.load_profile("repro")
else:
    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    class _Settings:
        """Stub for ``hypothesis.settings``: decorator factory + profiles."""
        def __init__(self, *_args, **_kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*_args, **_kwargs):
            pass

        @staticmethod
        def load_profile(*_args, **_kwargs):
            pass

    _strategies = types.ModuleType("hypothesis.strategies")
    # any strategy constructor (integers, floats, sampled_from, ...) is
    # accepted and returns an inert placeholder — @given never runs them.
    _strategies.__getattr__ = lambda name: (lambda *a, **k: None)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.strategies = _strategies
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None,
        function_scoped_fixture=None)
    _hyp.assume = lambda *a, **k: True
    _hyp.example = lambda *a, **k: (lambda fn: fn)
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _strategies


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
