"""Shared test config. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device (the 512-device override belongs to
the dry-run only)."""
import numpy as np
import pytest
from hypothesis import HealthCheck, settings

# jit compilation makes single examples slow; disable deadlines globally.
settings.register_profile(
    "repro",
    deadline=None,
    max_examples=15,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
