"""Construction fast path vs the levelwise oracle (bit-identical contract).

The acceptance bar for the fused builder: every leaf of the produced
``WaveletMatrix`` (bitvector words, zeros, rank superblock/block tables,
select samples) must equal the levelwise baseline's exactly, across
alphabet sizes, τ, big-step backends, and awkward (odd / non-multiple-of-
block) lengths — on the XLA fast path, the historical step path, and the
kernel (interpret-mode) path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.wavelet_matrix import (build_wavelet_matrix,
                                       build_wavelet_matrix_levelwise)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


@pytest.mark.parametrize("sigma", [2, 256, 1 << 16])
@pytest.mark.parametrize("tau", [4, 8])
@pytest.mark.parametrize("big_step", ["compose", "radix", "xla"])
def test_fused_matches_levelwise_oracle(sigma, tau, big_step):
    rng = np.random.default_rng(sigma * 31 + tau)
    for n in (1, 2, 33, 777, 1025):          # odd / non-block-multiple n
        seq = jnp.asarray(rng.integers(0, sigma, n).astype(np.uint32))
        fused = build_wavelet_matrix(seq, sigma, tau=tau, big_step=big_step,
                                     sample_rate=128)
        oracle = build_wavelet_matrix_levelwise(seq, sigma, sample_rate=128)
        assert _leaves_equal(fused, oracle), (n, sigma, tau, big_step)


@pytest.mark.parametrize("tau", [4, 8])
@pytest.mark.parametrize("big_step", ["compose", "radix", "xla"])
def test_fused_matches_step_path(tau, big_step):
    """Fast path vs the historical step-by-step XLA path (fused=False)."""
    rng = np.random.default_rng(7 * tau)
    for n, sigma in ((501, 2), (1337, 256), (900, 1 << 16)):
        seq = jnp.asarray(rng.integers(0, sigma, n).astype(np.uint32))
        fused = build_wavelet_matrix(seq, sigma, tau=tau, big_step=big_step,
                                     sample_rate=128)
        steps = build_wavelet_matrix(seq, sigma, tau=tau, big_step=big_step,
                                     sample_rate=128, fused=False)
        assert _leaves_equal(fused, steps), (n, sigma, tau, big_step)


@pytest.mark.parametrize("sigma,tau", [(256, 8), (1 << 16, 8), (37, 4)])
def test_kernel_path_matches(sigma, tau):
    """use_kernels=True (Pallas, interpret mode off-TPU) is bit-identical."""
    rng = np.random.default_rng(11)
    seq = jnp.asarray(rng.integers(0, sigma, 1500).astype(np.uint32))
    fused = build_wavelet_matrix(seq, sigma, tau=tau, sample_rate=128)
    kern = build_wavelet_matrix(seq, sigma, tau=tau, sample_rate=128,
                                use_kernels=True)
    assert _leaves_equal(fused, kern)


def test_fused_builder_is_jit_and_vmap_safe():
    """The whole fast-path builder jits and vmaps (the shard-build modes)."""
    import functools
    rng = np.random.default_rng(3)
    sigma, n, S = 97, 512, 4
    shards = jnp.asarray(rng.integers(0, sigma, (S, n)).astype(np.uint32))
    build = functools.partial(build_wavelet_matrix, sigma=sigma,
                              sample_rate=128, use_kernels=False)
    stacked = jax.vmap(build)(shards)
    jitted = jax.jit(build)
    for s in range(S):
        one = jitted(shards[s])
        got = jax.tree.map(lambda l: l[s], stacked)
        assert _leaves_equal(one, got), s


def test_queries_on_fused_build():
    """End-to-end: access/rank/select answers on a fused build are exact."""
    from repro.core.wavelet_matrix import wm_access, wm_rank, wm_select
    rng = np.random.default_rng(5)
    n, sigma = 2000, 300
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    wm = build_wavelet_matrix(jnp.asarray(seq), sigma, sample_rate=128)
    assert np.array_equal(np.asarray(wm_access(wm, jnp.arange(n))), seq)
    c = int(seq[0])
    idx = np.unique(rng.integers(0, n + 1, 32))
    r = np.asarray(wm_rank(wm, jnp.full(len(idx), c), jnp.asarray(idx)))
    assert np.array_equal(r, [(seq[:i] == c).sum() for i in idx])
    occ = np.flatnonzero(seq == c)
    ks = np.arange(min(8, len(occ)))
    s = np.asarray(wm_select(wm, jnp.full(len(ks), c), jnp.asarray(ks)))
    assert np.array_equal(s, occ[ks])


def test_path_selection_counters():
    """Every build advertises its chosen path through ``core.*`` counters:
    fused vs scatter at the builder level, kernel vs xla per level step."""
    obs.REGISTRY.reset()
    rng = np.random.default_rng(13)
    seq = jnp.asarray(rng.integers(0, 256, 400).astype(np.uint32))
    build_wavelet_matrix(seq, 256, sample_rate=128, use_kernels=False)
    build_wavelet_matrix(seq, 256, sample_rate=128, fused=False)
    snap = obs.REGISTRY.snapshot()["counters"]
    assert snap["core.build{builder=wm,path=fused}"] == 1
    assert snap["core.build{builder=wm,path=scatter}"] == 1
    # sigma=256 → 8 levels, all stepped on the XLA impl
    assert snap["core.level_step{builder=wm,impl=xla}"] == 8
    assert "core.level_step{builder=wm,impl=kernel}" not in snap

    obs.REGISTRY.reset()
    build_wavelet_matrix(seq, 256, sample_rate=128, use_kernels=True)
    snap = obs.REGISTRY.snapshot()["counters"]
    assert snap["core.level_step{builder=wm,impl=kernel}"] == 8
    traces = {k: v for k, v in snap.items() if k.startswith("kernels.trace")}
    assert any("op=wm_level_step_fused" in k for k in traces)


def test_path_counters_fire_at_trace_time():
    """Under jit the Python-side counters fire once per trace, not once
    per call — steady-state serving stays zero-overhead by construction."""
    import functools
    obs.REGISTRY.reset()
    rng = np.random.default_rng(17)
    seq = jnp.asarray(rng.integers(0, 64, 256).astype(np.uint32))
    build = jax.jit(functools.partial(build_wavelet_matrix, sigma=64,
                                      sample_rate=128, use_kernels=False))
    jax.block_until_ready(build(seq).zeros)
    jax.block_until_ready(build(seq).zeros)     # cache hit: no new trace
    snap = obs.REGISTRY.snapshot()["counters"]
    assert snap["core.build{builder=wm,path=fused}"] == 1
    assert snap["core.level_step{builder=wm,impl=xla}"] == 6


def test_shard_build_jit_loop_matches():
    """jit_loop sequential builds equal the unjitted loop exactly."""
    from repro.data.shard_build import build_shards_stacked
    rng = np.random.default_rng(9)
    shards = rng.integers(0, 64, (3, 256)).astype(np.uint32)

    def build_one(s):
        return build_wavelet_matrix(s, 64, sample_rate=128,
                                    use_kernels=False)

    a = build_shards_stacked(build_one, shards, parallel=False)
    b = build_shards_stacked(build_one, shards, parallel=False,
                             jit_loop=True)
    assert _leaves_equal(a, b)
