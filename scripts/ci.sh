#!/usr/bin/env bash
# One-stop CI entry point: tier-1 test suite, then the full-text index
# build+query smoke so the new subsystem is exercised end-to-end.
#
#   bash scripts/ci.sh            # tests + index smoke
#   bash scripts/ci.sh --bench    # also run the CI-sized benchmark pass
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== full-text index smoke =="
python -m repro.launch.index --smoke

echo "== range analytics smoke =="
python -m repro.launch.analytics --smoke

# telemetry: the launch layer AND the benchmarks must time through
# repro.obs (Stopwatch / time_compiled / timed_op) — a raw perf_counter
# there bypasses the metrics the SLO gate and the bench history read
echo "== obs time-source lint =="
if grep -rn "time\.perf_counter\|time\.time(" src/repro/launch/ benchmarks/; then
    echo "FAIL: raw time.* call in src/repro/launch/ or benchmarks/ — use repro.obs timers"
    exit 1
fi
echo "launch + benchmarks timing goes through repro.obs ✓"

# end-to-end metrics pipeline: serve with --metrics-dir, then validate
# the exported snapshot/JSONL (per-op latency histograms with nonzero
# counts, path-selection counters, correlated span events) and the SLO
# gate's pass/fail exit codes
echo "== obs export smoke =="
OBS_DIR="$(mktemp -d)"
trap 'rm -rf "$OBS_DIR"' EXIT
python -m repro.launch.analytics --smoke --metrics-dir "$OBS_DIR"
python - "$OBS_DIR" <<'PY'
import json, sys
from pathlib import Path
d = Path(sys.argv[1])
snap = json.loads((d / "snapshot.json").read_text())
hists = snap["histograms"]
for op in ("quantile", "count", "topk", "distinct"):
    h = hists[f"serve.analytics.{op}.latency_s"]
    assert h["count"] >= 1 and h["p99"] > 0, (op, h)
builds = {k: v for k, v in snap["counters"].items()
          if k.startswith("core.build")}
assert sum(builds.values()) >= 1, builds
events = [json.loads(ln) for ln in
          (d / "events.jsonl").read_text().splitlines() if ln.strip()]
spans = [e for e in events if e["kind"] == "span"]
assert any(e["name"] == "analytics.serve" for e in spans), spans
assert all("span_id" in e for e in spans)
print(f"obs export ✓ ({len(hists)} histograms, {len(events)} events)")
PY
python -m repro.launch.obs "$OBS_DIR" --slo 'analytics.*:p99_ms<=600000'
if python -m repro.launch.obs "$OBS_DIR" --slo 'analytics.*:qps>=1e18' \
        >/dev/null; then
    echo "FAIL: SLO gate did not reject an impossible bound"
    exit 1
fi
echo "SLO gate pass/fail exit codes ✓"

# every fault class injected against a live snapshot + engine: silent
# leaf corruption (detected by checksums, repaired bit-identically),
# primary-bitmap corruption (detected, rebuild signalled), torn/partial
# writes (skipped by step discovery), in-memory corruption (structural
# verify + repair), shard loss (degraded serving with coverage bounds),
# and the streaming-ingest crash sweep: the ingester is killed after
# every step of the two-phase shard commit protocol and must recover by
# journal replay to a serving state bit-identical to a clean build
# (plus torn-manifest, quarantine-coverage and hot-swap fencing checks)
echo "== fault-injection smoke (chaos) =="
python -m repro.launch.chaos --smoke

# overload-hardened query front-end: drive the seeded bursty trace at 5×
# pacing against a live QueryFrontend (admission queue, degradation
# ladder, circuit breakers, epoch pinning), then gate the accepted-
# request tail on the exported histograms — the declared serving SLO is
# the CLI's default 250 ms deadline. (The deterministic FakeClock
# overload scenarios — request storms, slow-shard breaker trips,
# deadline storms, stuck swaps — run inside the chaos smoke above.)
echo "== serving front-end overload smoke =="
FE_DIR="$(mktemp -d)"
python -m repro.launch.frontend --smoke --overload 5.0 \
    --metrics-dir "$FE_DIR"
python -m repro.launch.obs "$FE_DIR" --slo 'frontend.*:p99_ms<=250'
rm -rf "$FE_DIR"
echo "front-end overload + SLO gate ✓"

# (fused-vs-oracle equivalence and the interpret-mode kernel tests —
# tests/test_construction_fast.py, tests/test_segmented_construction.py,
# tests/test_kernels.py — already run as part of the tier-1 suite above;
# the bench smoke is the extra coverage. --fast writes to
# results/bench/construction.fast.json so the full-size perf trajectory
# in construction.json is never clobbered by CI-sized runs.)
echo "== construction fast-path smoke =="
python -m benchmarks.run --only construction --fast

# perf regression sentry: every benchmarks.run appends one record per
# (suite, row) to results/bench/history.jsonl; the regress CLI compares
# the latest run against a median-of-last-K same-host baseline with a
# MAD-scaled threshold. Soft gate: only CONFIRMED step regressions fail
# (noise-absorbing by design; --rel-floor 0.5 adds CI slack on top of the
# CLI's 0.25 default), and a missing/too-new history passes.
echo "== perf regression gate (fast records) =="
REGRESS_RC=0
python -m repro.launch.regress --fast --rel-floor 0.5 || REGRESS_RC=$?
if [[ "$REGRESS_RC" == "1" ]]; then
    echo "FAIL: confirmed perf regression vs bench history"
    exit 1
elif [[ "$REGRESS_RC" != "0" ]]; then
    echo "(no usable bench history yet — regression gate skipped)"
fi

echo "== fused tree-family equality smoke =="
python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro.core.huffman import build_huffman_wavelet_tree, huffman_codebook
from repro.core.multiary import build_multiary_wavelet_tree
from repro.core.wavelet_tree import build_wavelet_tree, build_wavelet_tree_dd

def eq(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

rng = np.random.default_rng(0)
n, sigma = 999, 64
seq = jnp.asarray(rng.integers(0, sigma, n).astype(np.uint32))
assert eq(build_wavelet_tree(seq, sigma),
          build_wavelet_tree(seq, sigma, fused=False)), "tree"
assert eq(build_wavelet_tree_dd(seq[:992], sigma, 8),
          build_wavelet_tree_dd(seq[:992], sigma, 8, fused=False)), "dd"
assert eq(build_multiary_wavelet_tree(seq, sigma, width=2),
          build_multiary_wavelet_tree(seq, sigma, width=2,
                                      fused=False)), "multiary"
freqs = np.bincount(np.asarray(seq), minlength=sigma) + 1
codes, lengths, max_len = huffman_codebook(freqs)
cj, lj = jnp.asarray(codes), jnp.asarray(lengths)
assert eq(build_huffman_wavelet_tree(seq, cj, lj, max_len),
          build_huffman_wavelet_tree(seq, cj, lj, max_len,
                                     fused=False)), "huffman"
print("fused tree-family equality ✓")
PY

if [[ "${1:-}" == "--bench" ]]; then
    echo "== benchmarks (fast) =="
    python -m benchmarks.run --fast
fi
