#!/usr/bin/env bash
# One-stop CI entry point: tier-1 test suite, then the full-text index
# build+query smoke so the new subsystem is exercised end-to-end.
#
#   bash scripts/ci.sh            # tests + index smoke
#   bash scripts/ci.sh --bench    # also run the CI-sized benchmark pass
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== full-text index smoke =="
python -m repro.launch.index --smoke

echo "== range analytics smoke =="
python -m repro.launch.analytics --smoke

# every fault class injected against a live snapshot + engine: silent
# leaf corruption (detected by checksums, repaired bit-identically),
# primary-bitmap corruption (detected, rebuild signalled), torn/partial
# writes (skipped by step discovery), in-memory corruption (structural
# verify + repair), shard loss (degraded serving with coverage bounds)
echo "== fault-injection smoke (chaos) =="
python -m repro.launch.chaos --smoke

# (fused-vs-oracle equivalence and the interpret-mode kernel tests —
# tests/test_construction_fast.py, tests/test_segmented_construction.py,
# tests/test_kernels.py — already run as part of the tier-1 suite above;
# the bench smoke is the extra coverage. --fast writes to
# results/bench/construction.fast.json so the full-size perf trajectory
# in construction.json is never clobbered by CI-sized runs.)
echo "== construction fast-path smoke =="
python -m benchmarks.run --only construction --fast

echo "== fused tree-family equality smoke =="
python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro.core.huffman import build_huffman_wavelet_tree, huffman_codebook
from repro.core.multiary import build_multiary_wavelet_tree
from repro.core.wavelet_tree import build_wavelet_tree, build_wavelet_tree_dd

def eq(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

rng = np.random.default_rng(0)
n, sigma = 999, 64
seq = jnp.asarray(rng.integers(0, sigma, n).astype(np.uint32))
assert eq(build_wavelet_tree(seq, sigma),
          build_wavelet_tree(seq, sigma, fused=False)), "tree"
assert eq(build_wavelet_tree_dd(seq[:992], sigma, 8),
          build_wavelet_tree_dd(seq[:992], sigma, 8, fused=False)), "dd"
assert eq(build_multiary_wavelet_tree(seq, sigma, width=2),
          build_multiary_wavelet_tree(seq, sigma, width=2,
                                      fused=False)), "multiary"
freqs = np.bincount(np.asarray(seq), minlength=sigma) + 1
codes, lengths, max_len = huffman_codebook(freqs)
cj, lj = jnp.asarray(codes), jnp.asarray(lengths)
assert eq(build_huffman_wavelet_tree(seq, cj, lj, max_len),
          build_huffman_wavelet_tree(seq, cj, lj, max_len,
                                     fused=False)), "huffman"
print("fused tree-family equality ✓")
PY

if [[ "${1:-}" == "--bench" ]]; then
    echo "== benchmarks (fast) =="
    python -m benchmarks.run --fast
fi
