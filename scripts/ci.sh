#!/usr/bin/env bash
# One-stop CI entry point: tier-1 test suite, then the full-text index
# build+query smoke so the new subsystem is exercised end-to-end.
#
#   bash scripts/ci.sh            # tests + index smoke
#   bash scripts/ci.sh --bench    # also run the CI-sized benchmark pass
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== full-text index smoke =="
python -m repro.launch.index --smoke

echo "== range analytics smoke =="
python -m repro.launch.analytics --smoke

# (fused-vs-oracle equivalence and the interpret-mode kernel tests —
# tests/test_construction_fast.py, tests/test_kernels.py — already run as
# part of the tier-1 suite above; the bench smoke is the extra coverage)
echo "== construction fast-path smoke =="
python -m benchmarks.run --only construction --fast

if [[ "${1:-}" == "--bench" ]]; then
    echo "== benchmarks (fast) =="
    python -m benchmarks.run --fast
fi
