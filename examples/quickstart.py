"""Quickstart: build the paper's structures and query them.

PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (build_wavelet_matrix, build_wavelet_tree,
                        wm_access, wm_rank, wm_select,
                        wt_access, wt_rank, wt_select)
from repro.core.huffman import build_huffman_wavelet_tree, huffman_codebook


def main():
    rng = np.random.default_rng(0)
    n, sigma = 100_000, 1000
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    seqj = jnp.asarray(seq)

    # --- balanced wavelet tree (paper Theorem 4.1: τ-chunked parallel) ----
    wt = build_wavelet_tree(seqj, sigma, tau=8)
    i = 12345
    c = int(wt_access(wt, jnp.int32(i)))
    print(f"wavelet tree: S[{i}] = {c} (truth {seq[i]})")
    r = int(wt_rank(wt, jnp.int32(c), jnp.int32(i)))
    print(f"rank_{c}(S, {i}) = {r} (truth {(seq[:i] == c).sum()})")
    s = int(wt_select(wt, jnp.int32(c), jnp.int32(r)))
    print(f"select_{c}(S, {r}) = {s} (the occurrence at/after {i}: "
          f"{np.flatnonzero(seq == c)[r]})")

    # --- wavelet matrix (Theorem 4.5) --------------------------------------
    wm = build_wavelet_matrix(seqj, sigma, tau=8)
    idx = jnp.asarray([0, 1, n // 2, n - 1])
    print("wavelet matrix access:", np.asarray(wm_access(wm, idx)),
          "truth:", seq[[0, 1, n // 2, n - 1]])
    top = int(np.bincount(seq).argmax())
    print(f"count of most frequent symbol {top}:",
          int(wm_rank(wm, jnp.int32(top), jnp.int32(n))),
          "truth:", int((seq == top).sum()))
    print("its 10th occurrence at:",
          int(wm_select(wm, jnp.int32(top), jnp.int32(9))),
          "truth:", int(np.flatnonzero(seq == top)[9]))

    # --- Huffman-shaped tree (Theorem 4.3): entropy-sized storage ----------
    zipf = rng.choice(sigma, size=n,
                      p=(lambda p: p / p.sum())(
                          np.arange(1, sigma + 1.) ** -1.3)).astype(np.uint32)
    freqs = np.bincount(zipf, minlength=sigma) + 1
    codes, lengths, max_len = huffman_codebook(freqs)
    hwt = build_huffman_wavelet_tree(jnp.asarray(zipf), jnp.asarray(codes),
                                     jnp.asarray(lengths), max_len)
    print(f"huffman tree on zipf data: {int(hwt.total_bits) / n:.2f} "
          f"bits/symbol vs {np.ceil(np.log2(sigma)):.0f} balanced")


if __name__ == "__main__":
    main()
