"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
a wavelet-matrix compressed corpus, with checkpointing and resume.

PYTHONPATH=src python examples/train_lm.py            # ~100M params, 200 steps
PYTHONPATH=src python examples/train_lm.py --tiny     # CI-sized
"""
import argparse

from repro.configs.base import ModelConfig
from repro.data import TokenBatcher, build_compressed_corpus, make_corpus
from repro.models.model import build_model
from repro.train import Trainer


def config_100m() -> ModelConfig:
    """~100M params: 12L, d=768, 12H (GQA kv=4), ff=2048, V=32000."""
    return ModelConfig(name="lm100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=4,
                       d_ff=2048, vocab_size=32000)


def config_tiny() -> ModelConfig:
    return ModelConfig(name="lm_tiny", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = config_tiny() if args.tiny else config_100m()
    steps = args.steps or (50 if args.tiny else 200)
    batch, seq = (8, 128) if args.tiny else (8, 512)
    batch = args.batch or batch
    seq = args.seq or seq

    model = build_model(cfg)
    nparams = sum(x.size for x in
                  __import__("jax").tree.leaves(model.init(0)))
    print(f"model {cfg.name}: {nparams/1e6:.1f}M params")

    # corpus lives compressed: ⌈log σ⌉ bits/token + o(n) directories
    toks = make_corpus(1 << (17 if args.tiny else 21), cfg.vocab_size, seed=0)
    corpus = build_compressed_corpus(toks, cfg.vocab_size,
                                     shard_bits=14 if args.tiny else 17)
    print(f"corpus: {corpus.n} tokens at {corpus.bits_per_token():.2f} "
          f"bits/token (raw 32) → {32/corpus.bits_per_token():.2f}× smaller")
    batcher = TokenBatcher(corpus=corpus, batch=batch, seq_len=seq, seed=0)

    trainer = Trainer(model, batcher, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(25, steps // 4), log_every=10,
                      base_lr=3e-4, warmup=20, total_steps=steps)
    if args.resume:
        print(f"resumed at step {trainer.maybe_resume()}")
    hist = trainer.run(steps)
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(started {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
