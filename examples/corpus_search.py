"""Substring search over the compressed corpus — the FM-index as a feature.

Builds a sharded FM-index over the synthetic Zipfian corpus and runs the
queries a retrieval/dedup pipeline needs: how often does this n-gram occur
(count), where (locate), and how is it distributed across shards — all
without ever materializing the raw text, and with the whole pattern batch
as ONE jitted vmapped query.

PYTHONPATH=src python examples/corpus_search.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_corpus
from repro.index import build_sharded_index


def main():
    vocab = 2048
    n = 1 << 15
    toks = np.asarray(make_corpus(n, vocab, seed=7), np.int64)
    idx = build_sharded_index(toks, vocab, shard_bits=12)
    print(f"{n} tokens, vocab {vocab}: {idx.num_shards} shards, "
          f"{idx.bits_per_token():.1f} bits/token index\n")

    # 1. n-gram frequency: sample 32 bigrams/4-grams from the corpus plus
    #    a few random ones, count them all in one jitted batch
    rng = np.random.default_rng(0)
    B, L = 32, 4
    pats = np.full((B, L), vocab, np.int32)
    lens = np.where(np.arange(B) % 2 == 0, 2, 4).astype(np.int32)
    for i in range(B - 4):
        s = int(rng.integers(0, n - lens[i]))
        pats[i, :lens[i]] = toks[s:s + lens[i]]
    for i in range(B - 4, B):                   # random → likely absent
        pats[i, :lens[i]] = rng.integers(0, vocab, lens[i])

    count = jax.jit(lambda ix, p, l: ix.count(p, l))
    counts = np.asarray(count(idx, jnp.asarray(pats), jnp.asarray(lens)))
    top = np.argsort(counts)[::-1][:5]
    print("most frequent sampled n-grams:")
    for i in top:
        print(f"  {pats[i, :lens[i]].tolist()}  ×{counts[i]}")
    print(f"random probes: {counts[B - 4:].tolist()} matches\n")

    # 2. duplication check: an exact repeated span is a dedup signal
    i_top = int(top[0])
    plen = int(lens[i_top])
    where = np.asarray(idx.locate(jnp.asarray(pats[i_top:i_top + 1]),
                                  jnp.asarray(lens[i_top:i_top + 1]),
                                  max_hits_per_shard=8))[0]
    hits = where[where >= 0]
    print(f"n-gram {pats[i_top, :plen].tolist()} located at "
          f"{hits[:8].tolist()}{'…' if counts[i_top] > 8 else ''}")
    for p0 in hits[:8]:
        assert np.array_equal(toks[p0:p0 + plen], pats[i_top, :plen])

    # 3. shard skew: is the n-gram uniformly spread or bursty?
    by_shard = np.asarray(idx.count_by_shard(
        jnp.asarray(pats[i_top:i_top + 1]),
        jnp.asarray(lens[i_top:i_top + 1])))[:, 0]
    print(f"per-shard counts: {by_shard.tolist()} "
          f"(uniform ≈ {int(counts[i_top]) / idx.num_shards:.1f})")

    # 4. verify a count against the raw stream — seam stitching makes
    #    count exact globally (shard-boundary-crossing matches included)
    want = int((np.lib.stride_tricks.sliding_window_view(toks, plen)
                == pats[i_top, :plen]).all(axis=1).sum())
    assert int(counts[i_top]) == want
    print("\ncount verified against naive scan of the raw stream ✓")


if __name__ == "__main__":
    main()
