"""Corpus analytics on the compressed store — rank/select as a feature.

Demonstrates the queries a data pipeline gets for free once the corpus is a
wavelet matrix: token frequencies without decompression, streak/position
queries via select, frequency-over-prefix drift via rank — the kind of
dedup / contamination / balance checks production pipelines run.

PYTHONPATH=src python examples/corpus_analytics.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import build_compressed_corpus, make_corpus, token_histogram


def main():
    vocab = 8192
    n = 1 << 19
    toks = make_corpus(n, vocab, seed=42, exponent=1.2)
    corpus = build_compressed_corpus(toks, vocab, shard_bits=16)
    print(f"{n} tokens, vocab {vocab}: {corpus.bits_per_token():.2f} "
          f"bits/token ({32/corpus.bits_per_token():.2f}× vs uint32)\n")

    # 1. frequency table — no decompression, read off the shard histograms
    hist = np.asarray(token_histogram(corpus))
    top = np.argsort(hist)[::-1][:5]
    print("top-5 tokens:", [(int(t), int(hist[t])) for t in top])

    # 2. frequency drift across the corpus (rank prefix-counts):
    #    is token t distributed uniformly or bursty?
    t = int(top[0])
    quarters = [int(corpus.count(jnp.int32(t), jnp.int32(i * n // 4)))
                for i in range(1, 5)]
    per_q = np.diff([0] + quarters)
    print(f"token {t} per-quarter counts: {per_q.tolist()} "
          f"(uniform would be ~{hist[t] // 4})")

    # 3. locate occurrences (select): positions of the k-th occurrence,
    #    e.g. for span sampling around rare tokens
    rare = int(np.flatnonzero(hist > 4)[-1])
    k = jnp.arange(min(5, int(hist[rare])))
    pos = np.asarray(corpus.locate(jnp.full(k.shape, rare), k))
    print(f"rare token {rare} (count {int(hist[rare])}) first occurrences "
          f"at {pos.tolist()}")
    # verify against the raw stream
    assert np.array_equal(pos, np.flatnonzero(toks == rare)[:len(pos)])

    # 4. gap statistics via consecutive selects — sample 2048 occurrence
    #    pairs; each pair costs two select queries, never touching the
    #    other ~n tokens
    occ = int(hist[t])
    rng = np.random.default_rng(0)
    ks = np.sort(rng.choice(occ - 1, size=min(2048, occ - 1),
                            replace=False)).astype(np.int32)
    p0 = np.asarray(corpus.locate(jnp.full(len(ks), t), jnp.asarray(ks)))
    p1 = np.asarray(corpus.locate(jnp.full(len(ks), t), jnp.asarray(ks + 1)))
    gaps = p1 - p0
    print(f"token {t} gap stats ({len(ks)} sampled pairs): "
          f"mean {gaps.mean():.1f}, p50 {np.percentile(gaps, 50):.0f}, "
          f"p99 {np.percentile(gaps, 99):.0f}")

    # 5. windowed decode — serving path (contiguous slice across shards)
    window = np.asarray(corpus.decode_slice(jnp.int32(n // 2 - 8), 16))
    print("decoded window around midpoint:", window.tolist())
    assert np.array_equal(window, toks[n // 2 - 8:n // 2 + 8]
                          .astype(window.dtype))

    # 6. range analytics (repro.analytics over the same shards): median
    #    token per region, band counts, per-region vocabulary diversity,
    #    heaviest tokens of a slice — all O(logσ)-ish queries, no decode
    q = n // 4
    los = jnp.asarray([0, q, 2 * q, 3 * q]); his = los + q
    med = np.asarray(corpus.range_quantile(los, his, (his - los) // 2))
    print(f"\nper-quarter median token: {med.tolist()}")
    band = np.asarray(corpus.range_count(los, his, 0, 256))
    print(f"tokens with id < 256 per quarter: {band.tolist()}")
    div = np.asarray(jax.jit(lambda a, b: corpus.range_distinct(a, b))(los, his))
    print(f"distinct tokens per quarter: {div.tolist()}")
    syms, cnts = corpus.range_topk(q, 3 * q, 3)
    print(f"top-3 tokens of the middle half: "
          f"{list(zip(np.asarray(syms).tolist(), np.asarray(cnts).tolist()))}")
    for i in range(4):
        seg = toks[int(los[i]):int(his[i])]
        assert med[i] == np.sort(seg)[len(seg) // 2]
        assert band[i] == int((seg < 256).sum())
        assert div[i] == len(np.unique(seg))
    print("\nall analytics verified against the raw stream ✓")


if __name__ == "__main__":
    main()
