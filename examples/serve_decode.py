"""Batched serving example: prefill + KV-cache decode on two families
(attention and SSM) with prompts streamed out of the compressed corpus.

PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data import build_compressed_corpus, make_corpus
from repro.models.model import build_model, zero_cache


def serve(arch: str, batch: int = 4, prompt_len: int = 48,
          decode_steps: int = 24):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(0)
    max_seq = prompt_len + decode_steps

    # prompts come straight out of the compressed store
    toks = make_corpus(1 << 16, cfg.vocab_size, seed=1)
    corpus = build_compressed_corpus(toks, cfg.vocab_size, shard_bits=14)
    starts = jnp.arange(batch, dtype=jnp.int32) * 999
    prompts = jax.vmap(lambda s: corpus.decode_slice(s, prompt_len))(starts)
    prompts = prompts.astype(jnp.int32)

    decode = jax.jit(model.decode_step)
    cache = zero_cache(cfg, batch, max_seq)
    # teacher-forced prompt ingestion
    logits = None
    for i in range(prompt_len):
        logits, cache = decode(params, prompts[:, i:i + 1], cache,
                               jnp.full((batch,), i, jnp.int32))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for s in range(decode_steps - 1):
        pos = jnp.full((batch,), prompt_len + s, jnp.int32)
        logits, cache = decode(params, tok, cache, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(out[-1])
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"{arch:>16} [{cfg.family}]: {batch}×{decode_steps} tokens "
          f"in {dt*1e3:6.1f} ms ({batch*(decode_steps-1)/dt:7.0f} tok/s) "
          f"sample: {gen[0, :8].tolist()}")


def main():
    for arch in ("qwen2_0_5b", "mamba2_370m", "jamba_v0_1_52b"):
        serve(arch)


if __name__ == "__main__":
    main()
